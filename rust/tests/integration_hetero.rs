//! Heterogeneous-cluster integration tests: mixed fleets end-to-end for
//! every scheduler, invariant validation on per-instance capacities,
//! and the homogeneous regression pin for the ClusterSpec refactor.

use accellm::coordinator::{AcceLlm, AcceLlmPrefix, Splitwise, Validated,
                           Vllm};
use accellm::registry::SchedulerRegistry;
use accellm::sim::{run, ClusterSpec, InstId, ReqId, RunReport, Scheduler,
                   SimConfig, SimCtx, Work, H100, LLAMA2_70B};

/// Registry construction + direct engine call (these tests compare
/// runs across hand-mutated configs, so they keep the raw `run`).
fn run_named(c: &SimConfig, trace: &accellm::workload::Trace, name: &str)
             -> RunReport {
    let mut s = SchedulerRegistry::build_spec(name, &c.cluster).unwrap();
    run(c, trace, s.as_mut())
}
use accellm::util::quickcheck::{check, prop_assert};
use accellm::util::rng::Pcg64;
use accellm::workload::{Trace, CHAT, MIXED};

/// Field-by-field bit equality of two runs (the refactor must not
/// perturb event ordering or float arithmetic).
fn assert_reports_identical(a: &RunReport, b: &RunReport, tag: &str) {
    assert_eq!(a.completed, b.completed, "{tag}: completed");
    assert_eq!(a.makespan, b.makespan, "{tag}: makespan");
    assert_eq!(a.ttft_mean, b.ttft_mean, "{tag}: ttft_mean");
    assert_eq!(a.ttft_p99, b.ttft_p99, "{tag}: ttft_p99");
    assert_eq!(a.tbt_mean, b.tbt_mean, "{tag}: tbt_mean");
    assert_eq!(a.tbt_max, b.tbt_max, "{tag}: tbt_max");
    assert_eq!(a.jct_mean, b.jct_mean, "{tag}: jct_mean");
    assert_eq!(a.cost_efficiency, b.cost_efficiency, "{tag}: cost_eff");
    assert_eq!(a.utilization, b.utilization, "{tag}: utilization");
    assert_eq!(a.peak_kv_bytes, b.peak_kv_bytes, "{tag}: peak_kv");
    assert_eq!(a.xfer_prefill_bytes, b.xfer_prefill_bytes, "{tag}: xfer");
    assert_eq!(a.xfer_replica_bytes, b.xfer_replica_bytes, "{tag}: replica");
    assert_eq!(a.prefix_hits, b.prefix_hits, "{tag}: prefix_hits");
    assert_eq!(a.prefix_saved_tokens, b.prefix_saved_tokens,
               "{tag}: saved tokens");
}

/// Regression pin for the ClusterSpec refactor: on a homogeneous
/// cluster, every spec path (legacy-shaped `SimConfig::homogeneous`,
/// parsed `ClusterSpec`, explicit flat-topology override at the device
/// bandwidth, and capacity-blind identity pairing) must produce the
/// SAME RunReport bit-for-bit — i.e. the per-instance machinery exactly
/// reproduces the old single-global-spec simulator.  (The absolute
/// values themselves are pinned by the calibration anchors in
/// `sim::perfmodel` and the scheduler unit tests.)
#[test]
fn homogeneous_results_pinned_across_spec_paths() {
    let trace = Trace::poisson(MIXED, 8.0, 60.0, 7);

    let legacy = SimConfig::homogeneous(H100, 4);
    let parsed = SimConfig::new(ClusterSpec::parse("h100x4").unwrap(),
                                LLAMA2_70B);
    let mut flat = SimConfig::homogeneous(H100, 4);
    flat.interconnect_bw = Some(H100.local_conn_bw);

    for sched in SchedulerRegistry::sweep() {
        let r_legacy = run_named(&legacy, &trace, sched);
        let r_parsed = run_named(&parsed, &trace, sched);
        let r_flat = run_named(&flat, &trace, sched);
        assert_reports_identical(&r_legacy, &r_parsed,
                                 &format!("{sched}: legacy vs parsed"));
        assert_reports_identical(&r_legacy, &r_flat,
                                 &format!("{sched}: legacy vs flat-override"));
        assert_eq!(r_legacy.completed, trace.len(), "{sched}");
    }

    // Hardware-aware pairing degenerates to identity pairing on a
    // homogeneous cluster: `accellm` == `accellm-blind` bit-for-bit.
    let aware = run(&legacy, &trace, &mut AcceLlm::new(&legacy.cluster));
    let blind = run(&legacy, &trace,
                    &mut AcceLlm::with_identity_pairing(&legacy.cluster));
    assert_reports_identical(&aware, &blind, "aware vs blind (homogeneous)");
}

/// Acceptance: a mixed h100x4+910b2x4 run works end-to-end for all four
/// schedulers, under the full invariant validator (per-instance
/// capacities, replica/primary accounting).
#[test]
fn mixed_cluster_all_schedulers_validated() {
    let cluster = ClusterSpec::parse("mixed:h100x4+910b2x4").unwrap();
    let cfg = SimConfig::new(cluster, LLAMA2_70B);
    let trace = Trace::poisson(MIXED, 6.0, 30.0, 11);
    let mut scheds: Vec<(&str, Box<dyn Scheduler>)> = vec![
        ("accellm", Box::new(Validated::new(AcceLlm::new(&cfg.cluster)))),
        ("splitwise",
         Box::new(Validated::new(Splitwise::new(&cfg.cluster)))),
        ("vllm", Box::new(Validated::new(Vllm::new(cfg.cluster.len())))),
        ("accellm-prefix",
         Box::new(Validated::new(AcceLlmPrefix::new(&cfg.cluster)))),
        ("accellm-blind",
         Box::new(Validated::new(
             AcceLlm::with_identity_pairing(&cfg.cluster)))),
    ];
    for (name, s) in &mut scheds {
        let r = run(&cfg, &trace, s.as_mut());
        assert_eq!(r.completed, trace.len(), "{name}");
        assert_eq!(r.per_device.len(), 2, "{name}");
    }
}

/// Property: every scheduler completes every request on randomized
/// mixed-fleet scenarios spanning all four device types.
#[test]
fn prop_mixed_fleets_complete_all_requests() {
    const SPECS: [&str; 4] = [
        "mixed:h100x4+910b2x4",
        "h100x2+910b2x6",
        "a100x4+h100x4",
        "mi300xx2+910b2x2",
    ];

    #[derive(Debug)]
    struct Scenario {
        spec: &'static str,
        rate: f64,
        duration: f64,
        seed: u64,
    }

    check(
        10,
        |rng: &mut Pcg64| Scenario {
            spec: SPECS[rng.uniform_usize(0, SPECS.len() - 1)],
            rate: rng.uniform_f64(1.0, 10.0),
            duration: rng.uniform_f64(5.0, 25.0),
            seed: rng.next_u64(),
        },
        |sc| {
            let cluster = ClusterSpec::parse(sc.spec).unwrap();
            let cfg = SimConfig::new(cluster, LLAMA2_70B);
            let trace = Trace::poisson(MIXED, sc.rate, sc.duration, sc.seed);
            if trace.is_empty() {
                return Ok(());
            }
            for name in SchedulerRegistry::sweep() {
                let r = run_named(&cfg, &trace, name);
                prop_assert(r.completed == trace.len(),
                            &format!("{name} on {}: {}/{} completed",
                                     sc.spec, r.completed, trace.len()))?;
                let class_tokens: u64 =
                    r.per_device.iter().map(|d| d.decode_tokens).sum();
                let want: u64 = trace
                    .requests
                    .iter()
                    .map(|q| q.decode_len as u64)
                    .sum();
                prop_assert(class_tokens == want,
                            &format!("{name} on {}: class tokens {} != {}",
                                     sc.spec, class_tokens, want))?;
            }
            Ok(())
        },
    );
}

/// Capacity-weighted CHWBL composes with the session workloads on a
/// mixed cluster: determinism + nonzero locality.
#[test]
fn mixed_cluster_prefix_routing_deterministic_with_hits() {
    let cluster = ClusterSpec::parse("mixed:h100x4+910b2x4").unwrap();
    let cfg = SimConfig::new(cluster, LLAMA2_70B);
    let trace = Trace::generate(CHAT, 4.0, 40.0, 13);
    let r1 = run_named(&cfg, &trace, "accellm-prefix");
    let r2 = run_named(&cfg, &trace, "accellm-prefix");
    assert_eq!(r1.completed, trace.len());
    assert!(r1.prefix_hit_rate > 0.2, "hit rate {}", r1.prefix_hit_rate);
    assert_reports_identical(&r1, &r2, "prefix determinism (mixed)");
}

/// Wrapper that audits every routing decision of hardware-aware
/// AcceLLM: the chosen pair must be strictly under its
/// capacity-weighted CHWBL bound at decision time.
struct RoutingAudit {
    inner: AcceLlm,
    checked: usize,
}

impl Scheduler for RoutingAudit {
    fn name(&self) -> &'static str {
        "routing-audit"
    }

    fn init(&mut self, ctx: &mut SimCtx) {
        self.inner.init(ctx);
    }

    fn on_arrival(&mut self, ctx: &mut SimCtx, req: ReqId) {
        let pair = self.inner.pick_pair(ctx, req);
        let router = self
            .inner
            .router()
            .expect("hardware-aware router must be active on a mixed fleet");
        let loads: Vec<usize> = (0..self.inner.n_pairs())
            .map(|p| self.inner.pair_load(p))
            .collect();
        let bound = router.load_bound_for(pair, &loads);
        assert!(loads[pair] < bound,
                "req {req} routed to pair {pair} at load {} >= weighted \
                 CHWBL bound {bound} (loads {loads:?})",
                loads[pair]);
        self.checked += 1;
        self.inner.enqueue_on_pair(ctx, req, pair);
    }

    fn on_work_done(&mut self, ctx: &mut SimCtx, inst: InstId, work: Work,
                    completed: Vec<ReqId>) {
        self.inner.on_work_done(ctx, inst, work, completed);
    }

    fn on_transfer_done(&mut self, ctx: &mut SimCtx, src: InstId,
                        dst: InstId, req: ReqId) {
        self.inner.on_transfer_done(ctx, src, dst, req);
    }
}

/// Satellite invariant: capacity-weighted `pick_pair` never routes to a
/// pair at/above the weighted CHWBL bound — audited on every arrival of
/// a saturating run, with the shared-uplink contention model enabled.
#[test]
fn aware_routing_never_exceeds_weighted_chwbl_bound_under_contention() {
    let mut cluster = ClusterSpec::parse("mixed:h100x4+910b2x4").unwrap();
    cluster.set_network_bw(5e9);
    cluster.enable_contention(5e9);
    let cfg = SimConfig::new(cluster, LLAMA2_70B);
    let trace = Trace::poisson(MIXED, 12.0, 40.0, 19);
    let mut audit =
        RoutingAudit { inner: AcceLlm::new(&cfg.cluster), checked: 0 };
    let r = run(&cfg, &trace, &mut audit);
    assert_eq!(r.completed, trace.len());
    assert_eq!(audit.checked, trace.len(), "every arrival must be audited");
    assert_eq!(r.per_link.len(), 4);
}

/// Satellite pin: topology-aware pairing on a homogeneous cluster
/// reproduces the PR 2 identity layout bit-for-bit — with or without a
/// network model and the contention model — and never engages the
/// capacity-weighted router (so homogeneous routing stays the paper's
/// free-memory rule exactly; run-level bit-equality is pinned by
/// `homogeneous_results_pinned_across_spec_paths`).
#[test]
fn topology_aware_pairing_is_identity_on_homogeneous_clusters() {
    for n in [2usize, 4, 8, 16] {
        let cluster = ClusterSpec::homogeneous(H100, n);
        let s = AcceLlm::new(&cluster);
        for p in 0..n / 2 {
            assert_eq!(s.pair_members(p), (2 * p, 2 * p + 1), "n={n}");
        }
        assert!(s.router().is_none(), "n={n}");
    }
    let mut starved = ClusterSpec::homogeneous(H100, 4);
    starved.set_network_bw(1e9);
    starved.enable_contention(1e9);
    let s = AcceLlm::new(&starved);
    assert_eq!(s.pair_members(0), (0, 1));
    assert_eq!(s.pair_members(1), (2, 3));
    assert!(s.router().is_none());
}

/// Per-link transfer pricing: forcing every link to 1 GB/s must slow
/// Splitwise's hand-offs on the mixed cluster exactly like the global
/// override does (both paths meter identical bytes).
#[test]
fn topology_link_pricing_matches_flat_override() {
    let trace = Trace::poisson(MIXED, 6.0, 30.0, 17);
    // Path A: per-link topology, every link overridden to 1 GB/s.
    let mut cluster = ClusterSpec::parse("mixed:h100x4+910b2x4").unwrap();
    for a in 0..cluster.len() {
        for b in 0..cluster.len() {
            if a != b {
                cluster.set_link_bw(a, b, 1e9).unwrap();
            }
        }
    }
    let cfg_links = SimConfig::new(cluster, LLAMA2_70B);
    // Path B: the global flat override.
    let mut cfg_flat =
        SimConfig::new(ClusterSpec::parse("mixed:h100x4+910b2x4").unwrap(),
                       LLAMA2_70B);
    cfg_flat.interconnect_bw = Some(1e9);

    let ra = run_named(&cfg_links, &trace, "splitwise");
    let rb = run_named(&cfg_flat, &trace, "splitwise");
    assert_reports_identical(&ra, &rb, "link matrix vs flat override");
    // And the slow link must actually hurt vs the NVLink default.
    let cfg_fast =
        SimConfig::new(ClusterSpec::parse("mixed:h100x4+910b2x4").unwrap(),
                       LLAMA2_70B);
    let rf = run_named(&cfg_fast, &trace, "splitwise");
    assert!(ra.jct_mean > rf.jct_mean,
            "1 GB/s links {} must be slower than NVLink {}", ra.jct_mean,
            rf.jct_mean);
}
