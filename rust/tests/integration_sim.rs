//! Cross-module integration + property tests over the simulator stack:
//! workload -> scheduler -> engine -> metrics, for all three policies.

use accellm::registry::SchedulerRegistry;
use accellm::sim::{run, DeviceSpec, InstanceSpec, PerfModel, RunReport,
                   SimConfig, ASCEND_910B2, H100, LLAMA2_70B};
use accellm::util::quickcheck::{check, prop_assert};
use accellm::util::rng::Pcg64;
use accellm::workload::{Trace, WorkloadSpec, HEAVY, LIGHT, MIXED};

fn cfg(dev: DeviceSpec, n: usize) -> SimConfig {
    SimConfig::homogeneous(dev, n)
}

/// Registry construction + direct engine call (these tests pin engine
/// behavior under configs they mutate, so they keep the raw `run`).
fn run_named(c: &SimConfig, trace: &Trace, name: &str) -> RunReport {
    let mut s = SchedulerRegistry::build_spec(name, &c.cluster).unwrap();
    run(c, trace, s.as_mut())
}

/// Property: every scheduler completes every request of any trace, and
/// the core metric sanity conditions hold (conservation — DESIGN.md §7
/// invariant 3).
#[test]
fn prop_all_schedulers_complete_all_requests() {
    #[derive(Debug)]
    struct Scenario {
        workload: WorkloadSpec,
        rate: f64,
        duration: f64,
        n: usize,
        seed: u64,
        dev: DeviceSpec,
    }

    check(
        25,
        |rng: &mut Pcg64| Scenario {
            workload: *rng.choose(&[LIGHT, MIXED, HEAVY]).unwrap(),
            rate: rng.uniform_f64(0.5, 18.0),
            duration: rng.uniform_f64(5.0, 40.0),
            n: *rng.choose(&[2usize, 4, 8]).unwrap(),
            seed: rng.next_u64(),
            dev: if rng.next_f64() < 0.5 { H100 } else { ASCEND_910B2 },
        },
        |sc| {
            let trace = Trace::poisson(sc.workload, sc.rate, sc.duration,
                                       sc.seed);
            if trace.is_empty() {
                return Ok(());
            }
            let c = cfg(sc.dev, sc.n);
            for name in SchedulerRegistry::sweep() {
                let r = run_named(&c, &trace, name);
                prop_assert(r.completed == trace.len(),
                            &format!("{name}: {}/{} completed", r.completed,
                                     trace.len()))?;
                // Token conservation: exactly decode_len tokens per request.
                let want: u64 = trace
                    .requests
                    .iter()
                    .map(|q| q.decode_len as u64)
                    .sum();
                let got = (r.cost_efficiency * r.makespan
                    * r.n_instances as f64)
                    .round() as u64;
                prop_assert(got == want,
                            &format!("{name}: decode tokens {got} != {want}"))?;
                prop_assert(r.ttft_mean > 0.0 && r.tbt_mean > 0.0
                            && r.jct_mean > 0.0,
                            &format!("{name}: non-positive metric"))?;
                prop_assert(r.jct_p50 >= r.ttft_p50,
                            &format!("{name}: JCT < TTFT"))?;
                prop_assert(r.utilization <= 1.0 + 1e-9,
                            &format!("{name}: utilization {} > 1",
                                     r.utilization))?;
            }
            Ok(())
        },
    );
}

/// Determinism: identical (trace, scheduler) -> bit-identical report.
#[test]
fn sim_is_deterministic() {
    let trace = Trace::poisson(MIXED, 9.0, 40.0, 5);
    let c = cfg(H100, 4);
    for name in SchedulerRegistry::sweep() {
        let r1 = run_named(&c, &trace, name);
        let r2 = run_named(&c, &trace, name);
        assert_eq!(r1.jct_mean, r2.jct_mean, "{name}");
        assert_eq!(r1.ttft_p99, r2.ttft_p99, "{name}");
        assert_eq!(r1.cost_efficiency, r2.cost_efficiency, "{name}");
    }
}

/// The paper's headline ordering at saturation (mixed, H100, 4 inst):
/// AcceLLM >= Splitwise in cost-efficiency and <= in JCT; vLLM has the
/// worst TBT spikes; Splitwise idles.
#[test]
fn paper_headline_ordering() {
    let trace = Trace::poisson(MIXED, 20.0, 90.0, 17);
    let mut cfg_t = cfg(H100, 4);
    cfg_t.record_timeline = true;
    let mut reports = Vec::new();
    for name in SchedulerRegistry::sweep() {
        reports.push(run_named(&cfg_t, &trace, name));
    }
    let (acc, spl, _vll) = (&reports[0], &reports[1], &reports[2]);
    assert!(acc.cost_efficiency > spl.cost_efficiency);
    assert!(acc.jct_mean < spl.jct_mean);
    assert!(acc.utilization > spl.utilization + 0.05);

    // The worst-case-TBT comparison (paper Fig. 16) is a moderate-load
    // phenomenon: at deep overload every system's worst gap is dominated
    // by batch-cap queueing.  Compare at 8 req/s.
    let moderate = Trace::poisson(MIXED, 8.0, 60.0, 18);
    let acc_m = run_named(&cfg_t, &moderate, "accellm");
    let vll_m = run_named(&cfg_t, &moderate, "vllm");
    assert!(vll_m.tbt_max > 1.25 * acc_m.tbt_max,
            "vllm spikes must dominate: {} vs {}", vll_m.tbt_max,
            acc_m.tbt_max);
}

/// Ascend prefill-queue blowup (Figure 12b / 14b shape): Splitwise TTFT
/// explodes past ~6 req/s while AcceLLM's stays bounded.
#[test]
fn ascend_prefill_overload_shape() {
    let hi = Trace::poisson(MIXED, 10.0, 60.0, 23);
    let c = cfg(ASCEND_910B2, 4);
    let spl = run_named(&c, &hi, "splitwise");
    let acc = run_named(&c, &hi, "accellm");
    assert!(spl.ttft_mean > 3.0 * acc.ttft_mean,
            "spl {} vs acc {}", spl.ttft_mean, acc.ttft_mean);
}

/// Interconnect sweep sanity (Figure 10): throughput at 900 GB/s must
/// not be materially better than at 100 GB/s (both systems peak well
/// below NVLink), but 1 GB/s must hurt.
#[test]
fn interconnect_sweep_shape() {
    let trace = Trace::poisson(MIXED, 8.0, 40.0, 29);
    let run_bw = |name: &str, bw: f64| {
        let mut c = cfg(H100, 4);
        c.interconnect_bw = Some(bw);
        run_named(&c, &trace, name)
    };
    // Splitwise funnels EVERY prompt's KV through one prefill NIC: a
    // 1 GB/s link saturates (8 req/s x ~510 tok x 320 KiB ≈ 1.3 GB/s)
    // and JCT balloons.
    let spl_slow = run_bw("splitwise", 1e9);
    let spl_mid = run_bw("splitwise", 100e9);
    assert!(spl_slow.jct_mean > 1.3 * spl_mid.jct_mean,
            "splitwise must queue hand-offs: {} vs {}",
            spl_slow.jct_mean, spl_mid.jct_mean);
    // AcceLLM's data locality keeps it nearly insensitive: the prompt's
    // KV already lives where decode can start; only the replica stream
    // crosses the link (paper Figure 10 / Section 5.3).
    let acc_slow = run_bw("accellm", 1e9);
    let acc_mid = run_bw("accellm", 100e9);
    assert!(acc_slow.jct_mean < 1.1 * acc_mid.jct_mean,
            "accellm should tolerate a slow link: {} vs {}",
            acc_slow.jct_mean, acc_mid.jct_mean);
    // Above ~100 GB/s the link stops mattering for either system.
    let acc_fast = run_bw("accellm", 900e9);
    assert!((acc_fast.jct_mean - acc_mid.jct_mean).abs() / acc_mid.jct_mean
            < 0.05,
            "100 GB/s is already enough: {} vs {}", acc_mid.jct_mean,
            acc_fast.jct_mean);
}

/// Memory accounting: AcceLLM's peak per-instance KV must exceed the
/// replica-free baselines on the same trace (Figure 9 shape) but stay
/// within device capacity.
#[test]
fn redundancy_memory_overhead_shape() {
    let trace = Trace::poisson(MIXED, 8.0, 60.0, 31);
    let c = cfg(H100, 4);
    let acc = run_named(&c, &trace, "accellm");
    let vll = run_named(&c, &trace, "vllm");
    assert!(acc.peak_kv_bytes > vll.peak_kv_bytes,
            "replicas must cost memory: acc {} vllm {}",
            acc.peak_kv_bytes, vll.peak_kv_bytes);
    let capacity = PerfModel::new(InstanceSpec::new(H100), LLAMA2_70B)
        .kv_capacity_bytes();
    assert!(acc.peak_kv_bytes <= capacity, "over capacity");
}

/// Cluster scaling: 8 instances must sustain ~2x the 4-instance rate at
/// comparable JCT (paper's 4/8/16 grids).
#[test]
fn scaling_with_instances() {
    let t4 = Trace::poisson(MIXED, 8.0, 60.0, 37);
    let t8 = Trace::poisson(MIXED, 16.0, 60.0, 37);
    let c4 = cfg(H100, 4);
    let c8 = cfg(H100, 8);
    let r4 = run_named(&c4, &t4, "accellm");
    let r8 = run_named(&c8, &t8, "accellm");
    assert_eq!(r4.completed, t4.len());
    assert_eq!(r8.completed, t8.len());
    assert!(r8.jct_mean < r4.jct_mean * 1.5,
            "8-instance JCT blew up: {} vs {}", r8.jct_mean, r4.jct_mean);
}

/// Replica traffic is strictly an AcceLLM phenomenon and is small
/// relative to prefill hand-off (Figure 10's decomposition).
#[test]
fn replica_traffic_decomposition() {
    let trace = Trace::poisson(MIXED, 8.0, 60.0, 41);
    let c = cfg(H100, 4);
    let acc = run_named(&c, &trace, "accellm");
    let spl = run_named(&c, &trace, "splitwise");
    assert!(acc.xfer_replica_bytes > 0.0);
    assert_eq!(spl.xfer_replica_bytes, 0.0);
    // Replica updates are one KV line per token; prefill hand-off moves
    // whole prompts.  Ratio stays moderate.
    assert!(acc.xfer_replica_bytes < 3.0 * acc.xfer_prefill_bytes,
            "replica {} vs prefill {}", acc.xfer_replica_bytes,
            acc.xfer_prefill_bytes);
}
