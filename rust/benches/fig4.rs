//! Bench target: regenerate paper fig4 (see DESIGN.md §5 for the
//! workload/parameters) and write results/fig4.csv.
fn main() {
    let t0 = std::time::Instant::now();
    let f = accellm::eval::figure_by_id("fig4").expect("known figure id");
    std::fs::create_dir_all("results").unwrap();
    std::fs::write(format!("results/{}.csv", f.id), f.to_csv()).unwrap();
    f.print();
    eprintln!("[bench fig4] {} rows regenerated in {:?}", f.rows.len(), t0.elapsed());
}
