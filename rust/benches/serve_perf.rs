//! L1/L2 perf bench over the REAL artifacts: PJRT prefill latency per
//! bucket and decode-step latency/throughput per compiled batch size.
//! Skips gracefully when artifacts/ has not been built.
//!
//! These are the numbers behind EXPERIMENTS.md §Perf (CPU-PJRT testbed;
//! TPU projections are derived analytically in DESIGN.md §8).

use std::path::Path;
use std::time::Instant;

use accellm::runtime::Engine;

fn main() -> anyhow::Result<()> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("[bench serve_perf] artifacts/ missing — run `make \
                   artifacts`; skipping");
        return Ok(());
    }
    let t0 = Instant::now();
    let engine = Engine::load(Path::new("artifacts"))?;
    eprintln!("[bench serve_perf] engine load+compile: {:?}", t0.elapsed());
    let m = engine.model().clone();

    println!("-- prefill latency per bucket (batch=1) --");
    println!("{:>7} | {:>10} | {:>10}", "bucket", "ms (best)", "tok/s");
    for bucket in engine.prefill_buckets() {
        let tokens: Vec<i32> = (0..bucket as i32).map(|i| 1 + i % 200).collect();
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t = Instant::now();
            let out = engine.prefill(&tokens)?;
            std::hint::black_box(&out.logits);
            best = best.min(t.elapsed().as_secs_f64());
        }
        println!("{:>7} | {:>10.2} | {:>10.0}", bucket, best * 1e3,
                 bucket as f64 / best);
    }

    println!("-- decode step latency per compiled batch --");
    println!("{:>6} | {:>10} | {:>12} | {:>14}",
             "batch", "ms (best)", "tok/s", "upload MB/step");
    for batch in engine.decode_batches() {
        let cache = m.n_layers * batch * m.n_kv_heads * m.max_len * m.head_dim;
        let k = vec![0.01f32; cache];
        let v = vec![0.02f32; cache];
        let toks = vec![42i32; batch];
        let lens = vec![37i32; batch];
        let mut best = f64::INFINITY;
        for _ in 0..8 {
            let t = Instant::now();
            let out = engine.decode_step(batch, &toks, &k, &v, &lens)?;
            std::hint::black_box(&out.logits);
            best = best.min(t.elapsed().as_secs_f64());
        }
        println!("{:>6} | {:>10.2} | {:>12.0} | {:>14.1}",
                 batch, best * 1e3, batch as f64 / best,
                 2.0 * cache as f64 * 4.0 / 1e6);
    }
    eprintln!("[bench serve_perf] done in {:?}", t0.elapsed());
    Ok(())
}
