//! Bench target: AcceLLM design ablations (redundancy / rebalancing /
//! flip damping) — extension beyond the paper's own evaluation.
fn main() {
    let t0 = std::time::Instant::now();
    std::fs::create_dir_all("results").unwrap();
    for f in [accellm::eval::ablation_mechanisms(),
              accellm::eval::ablation_flip_slack()] {
        std::fs::write(format!("results/{}.csv", f.id), f.to_csv()).unwrap();
        f.print();
        println!();
    }
    eprintln!("[bench ablations] regenerated in {:?}", t0.elapsed());
}
