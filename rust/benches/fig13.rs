//! Bench target: regenerate paper fig13 (see DESIGN.md §5 for the
//! workload/parameters) and write results/fig13.csv.
fn main() {
    let t0 = std::time::Instant::now();
    let f = accellm::eval::figure_by_id("fig13").expect("known figure id");
    std::fs::create_dir_all("results").unwrap();
    std::fs::write(format!("results/{}.csv", f.id), f.to_csv()).unwrap();
    f.print();
    eprintln!("[bench fig13] {} rows regenerated in {:?}", f.rows.len(), t0.elapsed());
}
