//! Prefix-routing micro-benchmark: CHWBL route throughput vs holder
//! count, plus trie insert/lookup throughput at chat-like depths.
//!
//! The router sits on the per-arrival hot path of `accellm-prefix`, so
//! the target is routes/s far above any plausible cluster arrival rate
//! (millions/s; arrivals are thousands/s).  Run with:
//! `cargo bench --bench prefix_router_perf`

use std::time::Instant;

use accellm::prefix::{chunk_hash, ChwblRouter, PrefixIndex};
use accellm::util::rng::Pcg64;

const KEYS: usize = 200_000;
const REPS: usize = 4;

fn bench_router() {
    println!("{:>8} | {:>10} | {:>12} | {:>10}",
             "holders", "vnodes", "routes/s", "ns/route");
    for &n in &[2usize, 4, 8, 16, 32, 64] {
        let router = ChwblRouter::new(n, 64, 1.25);
        let mut rng = Pcg64::new(7);
        let keys: Vec<u64> = (0..KEYS).map(|_| rng.next_u64()).collect();
        let mut best = f64::INFINITY;
        let mut sink = 0usize;
        for _ in 0..REPS {
            let mut loads = vec![0usize; n];
            let t0 = Instant::now();
            for &k in &keys {
                let h = router.route(k, &loads);
                loads[h] += 1;
                sink = sink.wrapping_add(h);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let per_sec = KEYS as f64 / best;
        println!("{:>8} | {:>10} | {:>12.0} | {:>10.1}   (sink {})",
                 n, router.n_vnodes(), per_sec, 1e9 / per_sec,
                 sink % 10);
    }
}

fn bench_index() {
    // Chat-like streams: 64 sessions, prefixes growing to 192 chunks.
    println!();
    println!("{:>10} | {:>14} | {:>14}",
             "depth", "inserts/s", "lookups/s");
    for &depth in &[16usize, 64, 192] {
        let streams: Vec<u64> = (0..64u64).map(|s| s * 0x9e37 + 1).collect();
        let chunk_lists: Vec<Vec<u64>> = streams
            .iter()
            .map(|&s| (0..depth as u64).map(|j| chunk_hash(s, j)).collect())
            .collect();
        let mut best_ins = f64::INFINITY;
        let mut best_look = f64::INFINITY;
        for _ in 0..REPS {
            let mut ix = PrefixIndex::new(8, 1 << 20);
            let t0 = Instant::now();
            for (i, c) in chunk_lists.iter().enumerate() {
                ix.insert(i % 8, c, i as f64);
            }
            best_ins = best_ins.min(t0.elapsed().as_secs_f64());

            let t1 = Instant::now();
            let mut matched = 0usize;
            for c in &chunk_lists {
                if let Some((_, d)) = ix.best_match(c) {
                    matched += d;
                }
            }
            best_look = best_look.min(t1.elapsed().as_secs_f64());
            assert!(matched > 0);
        }
        let n = chunk_lists.len() as f64;
        println!("{:>10} | {:>14.0} | {:>14.0}",
                 depth, n / best_ins, n / best_look);
    }
}

fn main() {
    bench_router();
    bench_index();
}
