//! L3 perf bench: raw simulator throughput (simulated decode tokens per
//! wall-second and events per second) for each scheduler.  This is the
//! hot path of the evaluation harness — the §Perf target for L3 is that
//! a full figure grid (fig11) regenerates in seconds, not minutes.

use std::time::Instant;

use accellm::registry::SchedulerRegistry;
use accellm::sim::{run, SimConfig, H100};
use accellm::workload::{Trace, MIXED};

fn main() {
    let cfg = SimConfig::homogeneous(H100, 8);
    // Heavy trace: ~2.4k requests, ~1.2M simulated decode tokens.
    let trace = Trace::poisson(MIXED, 20.0, 120.0, 99);
    println!("trace: {} requests, {} total tokens", trace.len(),
             trace.total_tokens());
    println!("{:>10} | {:>10} | {:>14} | {:>12}",
             "scheduler", "wall ms", "sim tok/s", "tok/wall-ms");
    for name in ["accellm", "splitwise", "vllm"] {
        // Warm + 3 timed repetitions, keep the best (criterion-style min).
        let mut best = f64::INFINITY;
        let mut tokens = 0u64;
        for _ in 0..4 {
            let mut s =
                SchedulerRegistry::build_spec(name, &cfg.cluster).unwrap();
            let t0 = Instant::now();
            let r = run(&cfg, &trace, s.as_mut());
            let dt = t0.elapsed().as_secs_f64();
            assert_eq!(r.completed, trace.len());
            tokens = r.n_requests as u64; // placeholder, replaced below
            tokens = trace
                .requests
                .iter()
                .map(|q| q.decode_len as u64)
                .sum();
            best = best.min(dt);
        }
        println!("{:>10} | {:>10.1} | {:>14.0} | {:>12.0}",
                 name, best * 1e3, tokens as f64 / best,
                 tokens as f64 / (best * 1e3));
    }
    eprintln!("[bench sim_engine_perf] done");
}
