//! Bench target: regenerate paper table1 (see DESIGN.md §5 for the
//! workload/parameters) and write results/table1.csv.
fn main() {
    let t0 = std::time::Instant::now();
    let f = accellm::eval::figure_by_id("table1").expect("known figure id");
    std::fs::create_dir_all("results").unwrap();
    std::fs::write(format!("results/{}.csv", f.id), f.to_csv()).unwrap();
    f.print();
    eprintln!("[bench table1] {} rows regenerated in {:?}", f.rows.len(), t0.elapsed());
}
