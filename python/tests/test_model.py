"""L2 model correctness: prefill/decode consistency, KV migration
primitives, shape contracts the Rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(n_layers=2, max_len=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


def _empty_caches(cfg, batch):
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_len, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _insert(cfg, kcb, vcb, kc, vc, slot):
    """Host-style slot insert (what the Rust KV manager does)."""
    seq = kc.shape[2]
    pad = cfg.max_len - seq
    kreq = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vreq = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return M.kv_write_slot(kcb, vcb, kreq, vreq, jnp.int32(slot))


class TestPrefill:
    def test_shapes(self, params):
        toks = jnp.arange(8, dtype=jnp.int32)[None] % CFG.vocab
        logits, k, v = M.prefill(CFG, params, toks)
        assert logits.shape == (1, CFG.vocab)
        assert k.shape == (CFG.n_layers, CFG.n_kv_heads, 8, CFG.head_dim)
        assert v.shape == k.shape

    def test_deterministic(self, params):
        toks = jnp.arange(6, dtype=jnp.int32)[None]
        a = M.prefill(CFG, params, toks)
        b = M.prefill(CFG, params, toks)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))

    def test_padded_bucket_matches_exact(self, params):
        """Right-padding to a bucket with the true `length` passed in must
        reproduce the unpadded logits and KV prefix exactly — the Rust
        runtime relies on this for bucketed prefill."""
        toks = jnp.array([[9, 8, 7, 6, 5]], jnp.int32)
        exact_logits, exact_k, exact_v = M.prefill(CFG, params, toks)
        padded = jnp.pad(toks, ((0, 0), (0, 11)))  # bucket of 16
        pl, pk, pv = M.prefill(CFG, params, padded, jnp.int32(5))
        np.testing.assert_allclose(np.asarray(pl), np.asarray(exact_logits),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(pk[:, :, :5]),
                                   np.asarray(exact_k), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(pv[:, :, :5]),
                                   np.asarray(exact_v), rtol=1e-5, atol=1e-5)

    def test_prompt_sensitivity(self, params):
        a = M.prefill(CFG, params, jnp.array([[1, 2, 3, 4]], jnp.int32))
        b = M.prefill(CFG, params, jnp.array([[1, 2, 3, 5]], jnp.int32))
        assert not np.allclose(np.asarray(a[0]), np.asarray(b[0]))


class TestDecodeStep:
    def test_incremental_matches_prefill(self, params):
        """Gold consistency: prefill(t[0..n]) last logits == decode of
        token n over the cache of prefill(t[0..n-1])."""
        toks = (jnp.arange(8, dtype=jnp.int32) * 7 + 3)[None] % CFG.vocab
        logits_full, _, _ = M.prefill(CFG, params, toks)
        _, kc7, vc7 = M.prefill(CFG, params, toks[:, :7])
        kcb, vcb = _empty_caches(CFG, 2)
        kcb, vcb = _insert(CFG, kcb, vcb, kc7, vc7, 1)
        logits_d, k_new, v_new = M.decode_step(
            CFG, params,
            jnp.array([0, int(toks[0, 7])], jnp.int32), kcb, vcb,
            jnp.array([0, 7], jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_d[1]),
                                   np.asarray(logits_full[0]),
                                   rtol=2e-4, atol=2e-4)
        # New KV lines must equal the full prefill's row 7.
        _, kc8, vc8 = M.prefill(CFG, params, toks)
        np.testing.assert_allclose(np.asarray(k_new[:, 1]),
                                   np.asarray(kc8[:, :, 7]),
                                   rtol=2e-4, atol=2e-4)

    def test_new_line_shapes(self, params):
        B = 4
        kcb, vcb = _empty_caches(CFG, B)
        logits, k_new, v_new = M.decode_step(
            CFG, params, jnp.zeros(B, jnp.int32), kcb, vcb,
            jnp.zeros(B, jnp.int32))
        assert logits.shape == (B, CFG.vocab)
        assert k_new.shape == (CFG.n_layers, B, CFG.n_kv_heads, CFG.head_dim)
        assert v_new.shape == k_new.shape

    def test_empty_slots_are_finite(self, params):
        """Garbage-in empty slots must not poison real slots with NaN."""
        B = 2
        kcb, vcb = _empty_caches(CFG, B)
        _, kc, vc = M.prefill(CFG, params, jnp.array([[5, 6, 7]], jnp.int32))
        kcb, vcb = _insert(CFG, kcb, vcb, kc, vc, 0)
        logits, _, _ = M.decode_step(
            CFG, params, jnp.array([3, 0], jnp.int32), kcb, vcb,
            jnp.array([3, 0], jnp.int32))
        assert np.isfinite(np.asarray(logits)).all()

    def test_slot_isolation(self, params):
        """Decoding slot 0 must not depend on slot 1's contents."""
        _, kc, vc = M.prefill(CFG, params, jnp.array([[5, 6, 7]], jnp.int32))
        kcb1, vcb1 = _empty_caches(CFG, 2)
        kcb1, vcb1 = _insert(CFG, kcb1, vcb1, kc, vc, 0)
        kcb2 = kcb1.at[:, 1].set(123.0)
        vcb2 = vcb1.at[:, 1].set(-42.0)
        toks = jnp.array([3, 9], jnp.int32)
        lens = jnp.array([3, 4], jnp.int32)
        l1, _, _ = M.decode_step(CFG, params, toks, kcb1, vcb1, lens)
        l2, _, _ = M.decode_step(CFG, params, toks, kcb2, vcb2, lens)
        np.testing.assert_array_equal(np.asarray(l1[0]), np.asarray(l2[0]))


class TestKvSlots:
    def test_write_read_roundtrip(self, params):
        _, kc, vc = M.prefill(CFG, params,
                              jnp.arange(5, dtype=jnp.int32)[None])
        kcb, vcb = _empty_caches(CFG, 4)
        kcb, vcb = _insert(CFG, kcb, vcb, kc, vc, 2)
        kr, vr = M.kv_read_slot(kcb, vcb, jnp.int32(2))
        np.testing.assert_array_equal(np.asarray(kr[:, :, :5]),
                                      np.asarray(kc))
        np.testing.assert_array_equal(np.asarray(vr[:, :, :5]),
                                      np.asarray(vc))

    def test_write_does_not_touch_other_slots(self, params):
        kcb, vcb = _empty_caches(CFG, 3)
        kcb = kcb + 7.0
        _, kc, vc = M.prefill(CFG, params, jnp.array([[1, 2]], jnp.int32))
        kcb2, _ = _insert(CFG, kcb, vcb, kc, vc, 1)
        np.testing.assert_array_equal(np.asarray(kcb2[:, 0]),
                                      np.asarray(kcb[:, 0]))
        np.testing.assert_array_equal(np.asarray(kcb2[:, 2]),
                                      np.asarray(kcb[:, 2]))


class TestParamContract:
    def test_param_shapes_order_is_stable(self):
        """The Rust runtime replays this exact order from manifest.json."""
        names = [n for n, _ in CFG.param_shapes()]
        assert names[0] == "embed"
        assert names[-2:] == ["final_norm", "lm_head"]
        assert names[1:10] == [
            "layer0.attn_norm", "layer0.wq", "layer0.wk", "layer0.wv",
            "layer0.wo", "layer0.ffn_norm", "layer0.w_gate", "layer0.w_up",
            "layer0.w_down"]

    def test_param_count_matches_shapes(self):
        total = sum(int(np.prod(s)) for _, s in CFG.param_shapes())
        assert total == CFG.param_count()

    def test_presets_valid(self):
        for name, cfg in M.PRESETS.items():
            assert cfg.n_q_heads % cfg.n_kv_heads == 0, name
            assert cfg.param_count() > 0
