"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; fixed cases pin the exact configurations
the AOT artifacts are built with (the CORE correctness signal for the
serving path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

class TestDecodeAttention:
    @pytest.mark.parametrize("batch", [1, 4, 8])
    @pytest.mark.parametrize("max_len", [64, 256])
    def test_matches_ref_artifact_shapes(self, batch, max_len):
        """The exact (n_q=6, n_kv=3, d=64) config compiled into artifacts."""
        q = _rand(1, (batch, 6, 64), jnp.float32)
        k = _rand(2, (batch, 3, max_len, 64), jnp.float32)
        v = _rand(3, (batch, 3, max_len, 64), jnp.float32)
        lens = jnp.arange(1, batch + 1, dtype=jnp.int32) * (max_len // batch)
        out = A.decode_attention(q, k, v, lens)
        ref = R.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    def test_length_one(self):
        """Shortest possible valid KV (freshly prefilled single token)."""
        q = _rand(1, (2, 4, 32), jnp.float32)
        k = _rand(2, (2, 2, 128, 32), jnp.float32)
        v = _rand(3, (2, 2, 128, 32), jnp.float32)
        lens = jnp.array([1, 1], jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        ref = R.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    def test_full_cache(self):
        """KV cache completely full (length == max_len)."""
        q = _rand(1, (1, 4, 64), jnp.float32)
        k = _rand(2, (1, 4, 256, 64), jnp.float32)
        v = _rand(3, (1, 4, 256, 64), jnp.float32)
        lens = jnp.array([256], jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        ref = R.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    def test_zero_length_slot_yields_finite(self):
        """Empty batch slots must produce zeros, never NaN (coordinator
        relies on this for padded decode batches)."""
        q = _rand(1, (2, 4, 32), jnp.float32)
        k = _rand(2, (2, 2, 64, 32), jnp.float32)
        v = _rand(3, (2, 2, 64, 32), jnp.float32)
        lens = jnp.array([0, 5], jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        assert np.isfinite(np.asarray(out)).all()
        assert np.allclose(np.asarray(out[0]), 0.0)

    def test_mask_ignores_garbage_tail(self):
        """Bytes beyond `length` must not affect the result (paged-cache
        invariant: stale KV from evicted requests is invisible)."""
        q = _rand(1, (1, 4, 32), jnp.float32)
        k = _rand(2, (1, 2, 128, 32), jnp.float32)
        v = _rand(3, (1, 2, 128, 32), jnp.float32)
        lens = jnp.array([40], jnp.int32)
        out1 = A.decode_attention(q, k, v, lens)
        k2 = k.at[:, :, 40:].set(1e9)
        v2 = v.at[:, :, 40:].set(-1e9)
        out2 = A.decode_attention(q, k2, v2, lens)
        np.testing.assert_allclose(out1, out2, rtol=0, atol=0)

    @pytest.mark.parametrize("block_k", [16, 32, 128])
    def test_block_size_invariance(self, block_k):
        """Tiling must not change the math."""
        q = _rand(1, (2, 8, 64), jnp.float32)
        k = _rand(2, (2, 4, 128, 64), jnp.float32)
        v = _rand(3, (2, 4, 128, 64), jnp.float32)
        lens = jnp.array([77, 128], jnp.int32)
        out = A.decode_attention(q, k, v, lens, block_k=block_k)
        ref = R.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    def test_bfloat16(self):
        q = _rand(1, (2, 4, 64), jnp.bfloat16)
        k = _rand(2, (2, 2, 64, 64), jnp.bfloat16)
        v = _rand(3, (2, 2, 64, 64), jnp.bfloat16)
        lens = jnp.array([33, 64], jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        ref = R.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   **_tol(jnp.bfloat16))

    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.integers(1, 5),
        n_kv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([16, 32, 64]),
        max_len=st.sampled_from([32, 64, 160]),
        data=st.data(),
    )
    def test_hypothesis_sweep(self, batch, n_kv, group, d, max_len, data):
        n_q = n_kv * group
        lens_list = data.draw(st.lists(
            st.integers(1, max_len), min_size=batch, max_size=batch))
        q = _rand(1, (batch, n_q, d), jnp.float32)
        k = _rand(2, (batch, n_kv, max_len, d), jnp.float32)
        v = _rand(3, (batch, n_kv, max_len, d), jnp.float32)
        lens = jnp.array(lens_list, jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        ref = R.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Prefill attention
# ---------------------------------------------------------------------------

class TestPrefillAttention:
    @pytest.mark.parametrize("seq", [16, 32, 64, 128])
    def test_matches_ref_artifact_shapes(self, seq):
        q = _rand(1, (1, 6, seq, 64), jnp.float32)
        k = _rand(2, (1, 3, seq, 64), jnp.float32)
        v = _rand(3, (1, 3, seq, 64), jnp.float32)
        out = A.prefill_attention(q, k, v)
        ref = R.prefill_attention_ref(q, k, v)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    def test_causality(self):
        """Changing future tokens must not change past outputs."""
        seq = 64
        q = _rand(1, (1, 4, seq, 32), jnp.float32)
        k = _rand(2, (1, 2, seq, 32), jnp.float32)
        v = _rand(3, (1, 2, seq, 32), jnp.float32)
        out1 = A.prefill_attention(q, k, v)
        k2 = k.at[:, :, 48:].add(7.0)
        v2 = v.at[:, :, 48:].add(-3.0)
        out2 = A.prefill_attention(q, k2, v2)
        np.testing.assert_allclose(out1[:, :, :48], out2[:, :, :48],
                                   rtol=0, atol=0)

    @pytest.mark.parametrize("bq,bk", [(16, 16), (32, 16), (64, 64), (128, 32)])
    def test_block_size_invariance(self, bq, bk):
        q = _rand(1, (1, 4, 128, 32), jnp.float32)
        k = _rand(2, (1, 2, 128, 32), jnp.float32)
        v = _rand(3, (1, 2, 128, 32), jnp.float32)
        out = A.prefill_attention(q, k, v, block_q=bq, block_k=bk)
        ref = R.prefill_attention_ref(q, k, v)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    def test_single_token_prompt(self):
        q = _rand(1, (1, 2, 1, 16), jnp.float32)
        k = _rand(2, (1, 1, 1, 16), jnp.float32)
        v = _rand(3, (1, 1, 1, 16), jnp.float32)
        out = A.prefill_attention(q, k, v)
        # Single causal position attends only to itself: out == v broadcast.
        np.testing.assert_allclose(
            np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]), rtol=1e-6, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        batch=st.integers(1, 3),
        n_kv=st.sampled_from([1, 2, 3]),
        group=st.sampled_from([1, 2]),
        d=st.sampled_from([16, 64]),
        seq=st.sampled_from([8, 24, 64, 96]),
    )
    def test_hypothesis_sweep(self, batch, n_kv, group, d, seq):
        n_q = n_kv * group
        q = _rand(11, (batch, n_q, seq, d), jnp.float32)
        k = _rand(12, (batch, n_kv, seq, d), jnp.float32)
        v = _rand(13, (batch, n_kv, seq, d), jnp.float32)
        out = A.prefill_attention(q, k, v)
        ref = R.prefill_attention_ref(q, k, v)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_prefill_vs_decode_consistency(self):
        """Flash prefill and per-token decode must agree on the same data:
        row i of the prefill output == decode attention with length i+1."""
        seq, n_kv, group, d = 16, 2, 2, 32
        n_q = n_kv * group
        q = _rand(1, (1, n_q, seq, d), jnp.float32)
        k = _rand(2, (1, n_kv, seq, d), jnp.float32)
        v = _rand(3, (1, n_kv, seq, d), jnp.float32)
        pre = A.prefill_attention(q, k, v)
        for i in [0, 7, 15]:
            dec = A.decode_attention(
                q[:, :, i, :], k, v, jnp.array([i + 1], jnp.int32))
            np.testing.assert_allclose(dec[0], pre[0, :, i], rtol=1e-4,
                                       atol=1e-4)
