"""Layer-2 JAX model: a Llama-style decoder with an explicit KV cache.

This is the compute graph that gets AOT-lowered (``aot.py``) to HLO text
and executed from the Rust coordinator via PJRT.  Python never runs on
the request path — these functions exist only to be traced.

Entry points (all pure, weights passed as a flat list of arrays so the
Rust side can feed ``execute_b`` positionally):

* :func:`prefill`      — process a whole prompt (batch=1), return the last-
                         position logits and the generated KV cache.
* :func:`decode_step`  — one token for a fixed-size batch of slots over a
                         padded KV cache; returns logits + updated caches.
* :func:`kv_write_slot` / :func:`kv_read_slot` — device-side KV cache
                         migration primitives (insert a request's KV into a
                         batch slot / extract it), used by the Rust KV
                         manager for instance-to-instance transfers.

Attention inside prefill/decode calls the Layer-1 Pallas kernels
(``kernels/attention.py``); everything else is plain jnp and fuses in XLA.
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention, prefill_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyper-parameters (compiled into the HLO)."""

    name: str = "llama-tiny"
    vocab: int = 256  # byte-level tokenizer
    dim: int = 384
    n_layers: int = 6
    n_q_heads: int = 6
    n_kv_heads: int = 3  # GQA, group = 2
    head_dim: int = 64
    ffn: int = 1024
    max_len: int = 256  # padded KV cache length (decode slots)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def q_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_shapes())

    def param_shapes(self):
        """Flat (name, shape) list — THE canonical argument order.

        The Rust runtime replays this order when uploading weights; it is
        serialized into ``artifacts/manifest.json`` by ``aot.py``.
        """
        out = [("embed", (self.vocab, self.dim))]
        for i in range(self.n_layers):
            p = f"layer{i}."
            out += [
                (p + "attn_norm", (self.dim,)),
                (p + "wq", (self.dim, self.q_dim)),
                (p + "wk", (self.dim, self.kv_dim)),
                (p + "wv", (self.dim, self.kv_dim)),
                (p + "wo", (self.q_dim, self.dim)),
                (p + "ffn_norm", (self.dim,)),
                (p + "w_gate", (self.dim, self.ffn)),
                (p + "w_up", (self.dim, self.ffn)),
                (p + "w_down", (self.ffn, self.dim)),
            ]
        out += [("final_norm", (self.dim,)), ("lm_head", (self.dim, self.vocab))]
        return out


PRESETS = {
    "tiny": ModelConfig(),
    "small": ModelConfig(name="llama-small", dim=512, n_layers=8, n_q_heads=8,
                         n_kv_heads=4, ffn=1408, max_len=512),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Random-normal initialization (no pretrained weights are available
    offline — documented substitution in DESIGN.md §3).  Scaled 0.02 like
    GPT-2 so logits stay numerically tame over hundreds of decode steps."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
    return params


# ---------------------------------------------------------------------------
# Building blocks (plain jnp — fused by XLA)
# ---------------------------------------------------------------------------


def _rmsnorm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, positions, theta):
    """Rotary embedding.  x: [..., seq, n_heads, head_dim], positions: [..., seq]."""
    d = x.shape[-1]
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # [..., seq, d/2]
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _ffn(x, w_gate, w_up, w_down):
    g = x @ w_gate
    return (jax.nn.silu(g) * (x @ w_up)) @ w_down


def _unpack(cfg: ModelConfig, params: List[jnp.ndarray]):
    embed = params[0]
    layers = []
    for i in range(cfg.n_layers):
        base = 1 + 9 * i
        layers.append(params[base:base + 9])
    final_norm, lm_head = params[-2], params[-1]
    return embed, layers, final_norm, lm_head


# ---------------------------------------------------------------------------
# Prefill: batch=1, full prompt
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: List[jnp.ndarray], tokens: jnp.ndarray,
            length: jnp.ndarray | None = None):
    """Process a prompt.

    tokens: [1, seq] int32 — right-padded to the compiled bucket size.
    length: [] int32 — true prompt length (logits are taken at position
            ``length - 1``; right-pad tokens are causal-masked away for
            every position before that, so they cannot affect the
            result).  Defaults to seq.
    Returns (logits[1, vocab] at the last real position,
             k_cache[L, n_kv, seq, hd], v_cache[L, n_kv, seq, hd]).
    """
    embed, layers, final_norm, lm_head = _unpack(cfg, params)
    _, seq = tokens.shape
    if length is None:
        length = jnp.asarray(seq, jnp.int32)
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :]  # [1, seq]

    x = embed[tokens[0]][None]  # [1, seq, dim]
    ks, vs = [], []
    for (attn_norm, wq, wk, wv, wo, ffn_norm, w_gate, w_up, w_down) in layers:
        h = _rmsnorm(x, attn_norm, cfg.norm_eps)
        q = (h @ wq).reshape(1, seq, cfg.n_q_heads, cfg.head_dim)
        k = (h @ wk).reshape(1, seq, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ wv).reshape(1, seq, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, pos, cfg.rope_theta)
        k = _rope(k, pos, cfg.rope_theta)
        # kernels expect [batch, heads, seq, hd]
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        attn = prefill_attention(qt, kt, vt)  # [1, n_q, seq, hd]
        attn = attn.transpose(0, 2, 1, 3).reshape(1, seq, cfg.q_dim)
        x = x + attn @ wo
        h2 = _rmsnorm(x, ffn_norm, cfg.norm_eps)
        x = x + _ffn(h2, w_gate, w_up, w_down)
        ks.append(kt[0])  # [n_kv, seq, hd]
        vs.append(vt[0])

    # Last REAL position (causality guarantees pad positions after it
    # cannot have influenced positions <= length-1).
    x_last = jax.lax.dynamic_slice(x, (0, length - 1, 0), (1, 1, cfg.dim))
    x_last = _rmsnorm(x_last[:, 0, :], final_norm, cfg.norm_eps)  # [1, dim]
    logits = x_last @ lm_head  # [1, vocab]
    return logits, jnp.stack(ks), jnp.stack(vs)


# ---------------------------------------------------------------------------
# Decode: fixed batch of slots, padded cache
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params: List[jnp.ndarray],
                tokens: jnp.ndarray, k_cache: jnp.ndarray,
                v_cache: jnp.ndarray, lengths: jnp.ndarray):
    """One decode iteration for B slots.

    tokens:  [B] int32 — last generated token per slot.
    k_cache: [L, B, n_kv, max_len, hd] (same for v_cache).
    lengths: [B] int32 — tokens already cached per slot; the new token's
             KV lines are written at index ``lengths[b]`` and attention
             spans ``lengths[b]+1`` positions.  Empty slots (length 0 with
             a dummy token) produce garbage logits the coordinator ignores.
    Returns (logits[B, vocab], k_new[L, B, n_kv, hd], v_new[L, B, n_kv, hd])
    — only the NEW KV lines: PJRT returns outputs as one tuple buffer that
    cannot be re-fed as separate inputs, so the Rust coordinator owns the
    canonical cache host-side and applies the new lines itself (tiny
    download instead of a full-cache round trip per step).
    """
    embed, layers, final_norm, lm_head = _unpack(cfg, params)
    B = tokens.shape[0]

    x = embed[tokens]  # [B, dim]
    new_ks, new_vs = [], []
    for li, (attn_norm, wq, wk, wv, wo, ffn_norm, w_gate, w_up, w_down) in \
            enumerate(layers):
        h = _rmsnorm(x, attn_norm, cfg.norm_eps)
        q = (h @ wq).reshape(B, 1, cfg.n_q_heads, cfg.head_dim)
        k = (h @ wk).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ wv).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, lengths[:, None], cfg.rope_theta)[:, 0]  # [B, n_q, hd]
        k = _rope(k, lengths[:, None], cfg.rope_theta)[:, 0]  # [B, n_kv, hd]
        v = v[:, 0]

        # Scatter the new KV lines into the cache at position lengths[b].
        def write(cache_b, new_b, pos_b):
            # cache_b: [n_kv, max_len, hd], new_b: [n_kv, hd]
            return jax.lax.dynamic_update_slice(
                cache_b, new_b[:, None, :], (0, pos_b, 0))

        k_l = jax.vmap(write)(k_cache[li], k, lengths)  # [B, n_kv, M, hd]
        v_l = jax.vmap(write)(v_cache[li], v, lengths)
        new_ks.append(k)  # [B, n_kv, hd] — just this token's lines
        new_vs.append(v)

        attn = decode_attention(q, k_l, v_l, lengths + 1)  # [B, n_q, hd]
        x = x + attn.reshape(B, cfg.q_dim) @ wo
        h2 = _rmsnorm(x, ffn_norm, cfg.norm_eps)
        x = x + _ffn(h2, w_gate, w_up, w_down)

    x = _rmsnorm(x, final_norm, cfg.norm_eps)
    logits = x @ lm_head
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


# ---------------------------------------------------------------------------
# Device-side KV migration primitives
# ---------------------------------------------------------------------------


def kv_write_slot(k_cache, v_cache, k_req, v_req, slot):
    """Insert one request's (padded) KV into batch slot ``slot``.

    k_cache: [L, B, n_kv, M, hd];  k_req: [L, n_kv, M, hd];  slot: [] int32.
    The whole M row is replaced — the valid prefix is tracked Rust-side.
    """
    k = jax.lax.dynamic_update_slice(
        k_cache, k_req[:, None], (0, slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        v_cache, v_req[:, None], (0, slot, 0, 0, 0))
    return k, v


def kv_read_slot(k_cache, v_cache, slot):
    """Extract one slot's KV row (for completion hand-off or migration)."""
    L, B, n_kv, M, hd = k_cache.shape
    k = jax.lax.dynamic_slice(k_cache, (0, slot, 0, 0, 0), (L, 1, n_kv, M, hd))
    v = jax.lax.dynamic_slice(v_cache, (0, slot, 0, 0, 0), (L, 1, n_kv, M, hd))
    return k[:, 0], v[:, 0]
