"""AOT bridge: lower the JAX model to HLO *text* + export weights.

Run once at build time (``make artifacts``); the Rust coordinator then
loads ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file``,
compiles them on the PJRT CPU client and executes them with device-
resident buffers.  Python never runs on the request path.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emitted artifact set (per model preset):

* ``prefill_s{S}.hlo.txt``  S in PREFILL_BUCKETS — prompt processing, batch=1
* ``decode_b{B}.hlo.txt``   B in DECODE_BATCHES  — one token for B slots
* ``kv_write_b{B}.hlo.txt`` / ``kv_read_b{B}.hlo.txt`` — device-side KV
  slot insert/extract (the Rust KV manager's migration primitives)
* ``weights.bin``           — raw little-endian f32, canonical param order
* ``manifest.json``         — config + param table + artifact index

Usage: ``python -m compile.aot --out-dir ../artifacts [--preset tiny]``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

PREFILL_BUCKETS = [16, 32, 64, 128]
DECODE_BATCHES = [1, 4, 8]
KV_BATCHES = [4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_prefill(cfg: M.ModelConfig, seq: int) -> str:
    param_specs = [_spec(s) for _, s in cfg.param_shapes()]
    tok_spec = _spec((1, seq), jnp.int32)
    len_spec = _spec((), jnp.int32)  # true prompt length within the bucket

    def fn(*args):
        params, tokens, length = list(args[:-2]), args[-2], args[-1]
        return M.prefill(cfg, params, tokens, length)

    return to_hlo_text(jax.jit(fn).lower(*param_specs, tok_spec, len_spec))


def lower_decode(cfg: M.ModelConfig, batch: int) -> str:
    param_specs = [_spec(s) for _, s in cfg.param_shapes()]
    cache = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_len, cfg.head_dim)
    specs = param_specs + [
        _spec((batch,), jnp.int32),  # tokens
        _spec(cache),                # k_cache
        _spec(cache),                # v_cache
        _spec((batch,), jnp.int32),  # lengths
    ]

    def fn(*args):
        params = list(args[:-4])
        tokens, k_cache, v_cache, lengths = args[-4:]
        return M.decode_step(cfg, params, tokens, k_cache, v_cache, lengths)

    # No donation: the caches are inputs only (outputs are just the new
    # KV lines — see model.decode_step docstring for the PJRT rationale).
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_kv_write(cfg: M.ModelConfig, batch: int) -> str:
    cache = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_len, cfg.head_dim)
    row = (cfg.n_layers, cfg.n_kv_heads, cfg.max_len, cfg.head_dim)
    specs = [_spec(cache), _spec(cache), _spec(row), _spec(row),
             _spec((), jnp.int32)]
    return to_hlo_text(
        jax.jit(M.kv_write_slot, donate_argnums=(0, 1)).lower(*specs))


def lower_kv_read(cfg: M.ModelConfig, batch: int) -> str:
    cache = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_len, cfg.head_dim)
    specs = [_spec(cache), _spec(cache), _spec((), jnp.int32)]
    return to_hlo_text(jax.jit(M.kv_read_slot).lower(*specs))


def export(cfg: M.ModelConfig, out_dir: str, seed: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    def emit(name: str, text: str, kind: str, **meta):
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts.append({"name": name, "file": name + ".hlo.txt",
                          "kind": kind, **meta})
        print(f"  {name}: {len(text)} chars")

    for s in PREFILL_BUCKETS:
        emit(f"prefill_s{s}", lower_prefill(cfg, s), "prefill", seq=s)
    for b in DECODE_BATCHES:
        emit(f"decode_b{b}", lower_decode(cfg, b), "decode", batch=b)
    for b in KV_BATCHES:
        emit(f"kv_write_b{b}", lower_kv_write(cfg, b), "kv_write", batch=b)
        emit(f"kv_read_b{b}", lower_kv_read(cfg, b), "kv_read", batch=b)

    # Weights: raw little-endian f32 in canonical order.
    params = M.init_params(cfg, seed)
    offsets, off = [], 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for (name, shape), p in zip(cfg.param_shapes(), params):
            arr = np.asarray(p, dtype="<f4")
            f.write(arr.tobytes())
            offsets.append({"name": name, "shape": list(shape),
                            "offset": off, "numel": int(arr.size)})
            off += int(arr.size)

    manifest = {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "dim": cfg.dim,
            "n_layers": cfg.n_layers, "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
            "ffn": cfg.ffn, "max_len": cfg.max_len,
            "rope_theta": cfg.rope_theta, "norm_eps": cfg.norm_eps,
            "param_count": int(off),
        },
        "seed": seed,
        "params": offsets,
        "artifacts": artifacts,
        "prefill_buckets": PREFILL_BUCKETS,
        "decode_batches": DECODE_BATCHES,
        "kv_batches": KV_BATCHES,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  weights.bin: {off * 4} bytes ({off} f32)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.PRESETS[args.preset]
    print(f"AOT-lowering preset '{args.preset}' "
          f"({cfg.param_count():,} params) -> {args.out_dir}")
    export(cfg, args.out_dir, args.seed)
    # Build stamp so `make artifacts` is a no-op when inputs are unchanged.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write(cfg.name + "\n")
    print("done")


if __name__ == "__main__":
    main()
