"""Layer-1 Pallas attention kernels (TPU-shaped, run under interpret=True).

Two kernels cover the paper's compute hot-spots:

* ``decode_attention`` — single-query attention over a padded KV cache,
  the bandwidth-bound decode-phase operation the paper's performance
  model is built around (Section 3.3).  Flash-style running-softmax so
  the KV cache is read exactly once (IO-optimal), tiled ``block_k`` at a
  time: the BlockSpec + inner ``fori_loop`` expresses the HBM→VMEM
  streaming schedule that the CUDA original expressed with threadblocks.
* ``prefill_attention`` — blocked causal self-attention for the
  compute-bound prefill phase (Section 3.2), tiled over query blocks
  with the inner loop stopping at the causal diagonal.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
NVIDIA H100 / Ascend 910B2.  We re-think the kernels for the TPU memory
model — VMEM tiles instead of CUDA shared memory, MXU-friendly
(multiple-of-8 × 128) blocks instead of WMMA fragments.  ``interpret=True``
is mandatory on this CPU-PJRT image; real-TPU lowering emits Mosaic
custom-calls the CPU plugin cannot execute.

Both kernels are validated against the pure-jnp oracles in ``ref.py``
by ``python/tests/test_attention.py`` (pytest + hypothesis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Mask value: large-negative instead of -inf so that a fully-masked tile
# cannot poison the running max with NaNs (exp(-inf - -inf)).
_NEG_INF = -1e30

# Default KV tile: 128 rows — one MXU systolic pass per (8,128) q tile.
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_Q = 128


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of ``n`` that is <= preferred (keeps tiles aligned)."""
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                        max_len: int, scale: float):
    """Grid cell = (batch b, kv-head h).

    Block shapes (leading grid dims squeezed by indexing [0]):
      len_ref: [1] int32          — valid KV length of request b
      q_ref:   [1, group, d]      — the `group` query heads sharing kv-head h
      k_ref:   [1, 1, max_len, d] — kv-head h of request b's K cache
      v_ref:   [1, 1, max_len, d]
      o_ref:   [1, group, d]
    """
    length = len_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale  # [group, d]
    group = q.shape[0]
    nblocks = max_len // block_k

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # [group, block_k] — MXU matmul per tile
        pos = i * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = pos < length  # [1, block_k]
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)  # kill fully-masked tiles
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((group,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((group,), jnp.float32)
    acc0 = jnp.zeros_like(q)
    _, l, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, acc0))
    # length >= 1 is a caller invariant; guard anyway so padded batch slots
    # produce zeros instead of NaNs.
    denom = jnp.where(l > 0.0, l, 1.0)
    o_ref[0] = (acc / denom[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,  # [batch, n_q_heads, head_dim]
    k_cache: jnp.ndarray,  # [batch, n_kv_heads, max_len, head_dim]
    v_cache: jnp.ndarray,  # [batch, n_kv_heads, max_len, head_dim]
    lengths: jnp.ndarray,  # [batch] int32
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Flash-style decode attention; see module docstring.

    Returns [batch, n_q_heads, head_dim] in q.dtype.
    """
    batch, n_q, d = q.shape
    _, n_kv, max_len, _ = k_cache.shape
    assert n_q % n_kv == 0, "GQA requires n_q divisible by n_kv"
    group = n_q // n_kv
    bk = _pick_block(max_len, block_k)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _decode_attn_kernel, block_k=bk, max_len=max_len, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(batch, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),
            pl.BlockSpec((1, group, d), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, max_len, d), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, max_len, d), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_q, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# Prefill (causal) attention
# ---------------------------------------------------------------------------


def _prefill_attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int,
                         block_k: int, seq: int, scale: float):
    """Grid cell = (batch b, kv-head h, query-block iq).

    Block shapes:
      q_ref: [1, group, block_q, d]
      k_ref: [1, 1, seq, d]   (full KV row; tiles streamed by the loop)
      v_ref: [1, 1, seq, d]
      o_ref: [1, group, block_q, d]
    """
    iq = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32) * scale  # [group, block_q, d]
    group = q.shape[0]
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jnp.einsum("gqd,kd->gqk", q, k)  # [group, block_q, block_k]
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        causal = q_pos >= k_pos  # [block_q, block_k]
        s = jnp.where(causal[None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(causal[None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=2)
        acc_new = acc * alpha[..., None] + jnp.einsum("gqk,kd->gqd", p, v)
        return m_new, l_new, acc_new

    # Causal: only KV tiles at or below this query block's diagonal.
    nblocks = (iq + 1) * block_q // block_k
    m0 = jnp.full((group, block_q), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((group, block_q), jnp.float32)
    acc0 = jnp.zeros_like(q)
    _, l, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[..., None]).astype(o_ref.dtype)


def prefill_attention(
    q: jnp.ndarray,  # [batch, n_q_heads, seq, head_dim]
    k: jnp.ndarray,  # [batch, n_kv_heads, seq, head_dim]
    v: jnp.ndarray,  # [batch, n_kv_heads, seq, head_dim]
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Blocked causal flash attention for the prefill phase (GQA).

    Returns [batch, n_q_heads, seq, head_dim] in q.dtype.
    """
    batch, n_q, seq, d = q.shape
    n_kv = k.shape[1]
    assert n_q % n_kv == 0
    group = n_q // n_kv
    bq = _pick_block(seq, block_q)
    # block_k must divide block_q boundaries for the causal tile count.
    bk = _pick_block(seq, min(block_k, bq))
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _prefill_attn_kernel, block_q=bq, block_k=bk, seq=seq, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(batch, n_kv, seq // bq),
        in_specs=[
            pl.BlockSpec((1, group, bq, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, seq, d), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, seq, d), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, bq, d), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_q, seq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
