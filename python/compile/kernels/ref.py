"""Pure-jnp correctness oracles for the Pallas kernels.

These are the semantic ground truth the Pallas kernels in
``attention.py`` are validated against (pytest + hypothesis in
``python/tests/``).  They are deliberately written in the most obvious
way possible — no tiling, no running softmax — so that a mismatch always
points at the kernel, not the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,  # [batch, n_q_heads, head_dim]
    k_cache: jnp.ndarray,  # [batch, n_kv_heads, max_len, head_dim]
    v_cache: jnp.ndarray,  # [batch, n_kv_heads, max_len, head_dim]
    lengths: jnp.ndarray,  # [batch] int32 — valid KV length per request
) -> jnp.ndarray:
    """Single-token (decode-phase) attention over a padded KV cache.

    GQA: n_q_heads must be a multiple of n_kv_heads; query head h reads
    KV head ``h // (n_q_heads // n_kv_heads)``.
    Positions >= lengths[b] are masked out.
    Returns [batch, n_q_heads, head_dim].
    """
    b, n_q, d = q.shape
    _, n_kv, max_len, _ = k_cache.shape
    group = n_q // n_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    # Expand KV heads to match query heads.
    k = jnp.repeat(k_cache, group, axis=1)  # [b, n_q, max_len, d]
    v = jnp.repeat(v_cache, group, axis=1)

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    scores = jnp.einsum("bhd,bhld->bhl", qf, kf) * scale  # [b, n_q, max_len]
    pos = jnp.arange(max_len)[None, None, :]
    mask = pos < lengths[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = jnp.where(mask, probs, 0.0)
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhl,bhld->bhd", probs, vf)
    return out.astype(q.dtype)


def prefill_attention_ref(
    q: jnp.ndarray,  # [batch, n_q_heads, seq, head_dim]
    k: jnp.ndarray,  # [batch, n_kv_heads, seq, head_dim]
    v: jnp.ndarray,  # [batch, n_kv_heads, seq, head_dim]
) -> jnp.ndarray:
    """Causal self-attention for the prefill phase (GQA).

    Returns [batch, n_q_heads, seq, head_dim].
    """
    b, n_q, s, d = q.shape
    n_kv = k.shape[1]
    group = n_q // n_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)

    qf = q.astype(jnp.float32)
    kf = kx.astype(jnp.float32)
    vf = vx.astype(jnp.float32)

    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Root-mean-square layer norm (Llama style)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * (1.0 / jnp.sqrt(var + eps)) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def swiglu_ref(x, w_gate, w_up, w_down):
    """Llama FFN: down( silu(gate(x)) * up(x) )."""
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    act = g * (1.0 / (1.0 + jnp.exp(-g)))  # silu
    return ((act * u) @ w_down.astype(jnp.float32)).astype(x.dtype)
