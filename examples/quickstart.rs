//! Quickstart: simulate the three schedulers on the paper's mixed
//! workload, then (if `make artifacts` has been run) serve a few real
//! requests through the PJRT model under AcceLLM.
//!
//! Run: `cargo run --release --example quickstart`

use std::time::Duration;

use accellm::coordinator::{AcceLlm, Splitwise, Vllm};
use accellm::server::{serve_trace, ClusterConfig, ServePolicy, ServeRequest};
use accellm::sim::{run, InstanceSpec, PerfModel, Scheduler, SimConfig, H100,
                   LLAMA2_70B};
use accellm::workload::{Trace, MIXED};

fn main() -> anyhow::Result<()> {
    // ---- 1. Cluster simulation (the paper's evaluation substrate) ----
    println!("== simulated cluster: 4x H100 instances, mixed workload, \
              10 req/s ==");
    let cfg = SimConfig {
        model: PerfModel::new(InstanceSpec::new(H100), LLAMA2_70B),
        n_instances: 4,
        interconnect_bw: None,
        record_timeline: false,
    };
    let trace = Trace::poisson(MIXED, 10.0, 60.0, 42);
    println!("{:>10} | {:>9} | {:>8} | {:>8} | {:>7} | {:>5}",
             "scheduler", "tok/inst/s", "ttft ms", "tbt ms", "jct s", "util");
    let mut scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(AcceLlm::new(4)),
        Box::new(Splitwise::new(4)),
        Box::new(Vllm::new(4)),
    ];
    for s in &mut scheds {
        let r = run(&cfg, &trace, s.as_mut());
        assert_eq!(r.completed, trace.len());
        println!("{:>10} | {:>9.0} | {:>8.1} | {:>8.2} | {:>7.2} | {:>5.2}",
                 r.scheduler, r.cost_efficiency, r.ttft_mean * 1e3,
                 r.tbt_mean * 1e3, r.jct_mean, r.utilization);
    }

    // ---- 2. Real model serving over PJRT ----
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(artifacts/ not built — run `make artifacts` to also \
                  exercise the real serving path)");
        return Ok(());
    }
    println!("\n== real model (PJRT, AOT artifacts): 2 instances, AcceLLM ==");
    let reqs: Vec<ServeRequest> = (0..6)
        .map(|i| ServeRequest {
            id: i,
            prompt: format!("request number {i}: the scheduler should"),
            max_new_tokens: 16,
            arrival_offset: Duration::from_millis(200 * i),
        })
        .collect();
    let report = serve_trace(
        &ClusterConfig {
            artifacts_dir: "artifacts".into(),
            n_instances: 2,
            policy: ServePolicy::AcceLlm,
            slots: 8,
        },
        &reqs,
    )?;
    report.print_summary();
    Ok(())
}
