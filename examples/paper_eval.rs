//! Regenerate EVERY table and figure of the paper's evaluation into
//! `results/` (CSV per figure) and print a compact summary of the key
//! claims with pass/fail shape checks.
//!
//! Run: `cargo run --release --example paper_eval`

use accellm::eval::all_figures;

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("results")?;
    let figs = all_figures();
    for f in &figs {
        let path = format!("results/{}.csv", f.id);
        std::fs::write(&path, f.to_csv())?;
        println!("wrote {path} ({} rows) — {}", f.rows.len(), f.title);
    }

    // Headline shape checks from the regenerated data (fig11: mixed, H100).
    let fig11 = figs.iter().find(|f| f.id == "fig11").unwrap();
    let field = |row: &str, i: usize| -> f64 {
        row.split(',').nth(i).unwrap().parse().unwrap()
    };
    // At the highest swept rate with 4 instances: AcceLLM cost-eff vs both.
    let pick = |sched: &str, rate: &str| -> f64 {
        fig11
            .rows
            .iter()
            .find(|r| r.contains(&format!(",4,{sched},{rate},")))
            .map(|r| field(r, 5))
            .unwrap_or_else(|| panic!("no fig11 row for {sched}@{rate}"))
    };
    let (acc, spl, vll) = (pick("accellm", "23.0"), pick("splitwise", "23.0"),
                           pick("vllm", "23.0"));
    println!("\nheadline @ 23 req/s, 4x H100, mixed:");
    println!("  cost-eff  accellm {acc:.0}  splitwise {spl:.0}  vllm {vll:.0} \
              tok/inst/s");
    println!("  accellm vs splitwise: {:+.1}%", 100.0 * (acc / spl - 1.0));
    println!("  accellm vs vllm:      {:+.1}%", 100.0 * (acc / vll - 1.0));
    assert!(acc > spl, "AcceLLM must beat Splitwise at saturation");

    let fig16 = figs.iter().find(|f| f.id == "fig16").unwrap();
    println!("\nworst-case TBT (fig16):");
    for r in &fig16.rows {
        println!("  {r}");
    }
    println!("\npaper_eval OK — all outputs in results/");
    Ok(())
}
