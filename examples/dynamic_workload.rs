//! Dynamic-workload study (paper Section 3.5.3 / Figure 6): bursty
//! arrivals with idle valleys.  Shows why static disaggregation wastes
//! resources — Splitwise's dedicated prefill instances idle through the
//! valleys while its decode instances drown during bursts — and how
//! AcceLLM's dynamic instances absorb both phases.
//!
//! Run: `cargo run --release --example dynamic_workload`

use accellm::coordinator::by_name;
use accellm::sim::{run, InstanceSpec, PerfModel, SimConfig, H100, LLAMA2_70B};
use accellm::workload::{Trace, MIXED};

fn main() {
    // 30 s burst at 18 req/s — 30 s of near-silence — 30 s burst again.
    let phases = [(30.0, 18.0), (30.0, 0.3), (30.0, 18.0)];
    let trace = Trace::phased(MIXED, &phases, 2024);
    println!("bursty trace: {} requests over 90 s (phases {:?})",
             trace.len(), phases);

    let cfg = SimConfig {
        model: PerfModel::new(InstanceSpec::new(H100), LLAMA2_70B),
        n_instances: 4,
        interconnect_bw: None,
        record_timeline: true,
    };

    println!("\n{:>10} | {:>5} | {:>10} | {:>8} | {:>8} | {:>8} | {:>9}",
             "scheduler", "util", "tok/inst/s", "ttft ms", "p99 ms",
             "jct s", "tbt max ms");
    let mut results = Vec::new();
    for name in ["accellm", "splitwise", "vllm"] {
        let mut s = by_name(name, 4).unwrap();
        let r = run(&cfg, &trace, s.as_mut());
        assert_eq!(r.completed, trace.len());
        println!("{:>10} | {:>5.2} | {:>10.0} | {:>8.1} | {:>8.1} | {:>8.2} \
                  | {:>9.1}",
                 name, r.utilization, r.cost_efficiency, r.ttft_mean * 1e3,
                 r.ttft_p99 * 1e3, r.jct_mean, r.tbt_max * 1e3);
        results.push(r);
    }

    let acc = &results[0];
    let spl = &results[1];
    println!("\nAcceLLM vs Splitwise under bursts:");
    println!("  utilization   {:.2} vs {:.2}", acc.utilization, spl.utilization);
    println!("  JCT           {:.2}s vs {:.2}s  ({:+.0}%)", acc.jct_mean,
             spl.jct_mean, 100.0 * (acc.jct_mean / spl.jct_mean - 1.0));
    println!("  drain time    {:.1}s vs {:.1}s", acc.makespan, spl.makespan);
    assert!(acc.utilization > spl.utilization,
            "dynamic instances must out-utilize static disaggregation");
    assert!(acc.jct_mean < spl.jct_mean);
    println!("\ndynamic_workload OK");
}
