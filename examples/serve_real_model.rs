//! END-TO-END driver (DESIGN.md deliverable, recorded in
//! EXPERIMENTS.md): load the real AOT-compiled model and serve a batched
//! request workload under ALL THREE policies, reporting latency and
//! throughput, and verifying that greedy decoding produces IDENTICAL
//! text under every policy — the strongest cross-layer correctness
//! check we have (it fails if replica handover ever activates a stale
//! KV copy).
//!
//! Run: `make artifacts && cargo run --release --example serve_real_model`

use std::collections::HashMap;
use std::time::Duration;

use accellm::server::{serve_trace, ClusterConfig, ServePolicy, ServeRequest};
use accellm::util::rng::Pcg64;

fn build_workload(n: usize, rate: f64, seed: u64) -> Vec<ServeRequest> {
    let corpus = [
        "Large language model inference on large-scale systems",
        "The scheduling manager routes each request to one instance",
        "Prefill is compute bound while decoding is limited by memory",
        "Redundant KV cache copies enable zero-cost role conversion",
        "With two instances per pair, nearly all requests stay redundant",
        "Load balancing the decode batches reduces time between tokens",
        "When no prefill requests remain the instance switches back",
        "The key value cache grows by one line per generated token",
    ];
    let mut rng = Pcg64::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            t += rng.exponential(rate);
            ServeRequest {
                id: i as u64,
                prompt: corpus[i % corpus.len()]
                    .repeat(rng.uniform_usize(1, 2)),
                max_new_tokens: rng.uniform_usize(12, 40),
                arrival_offset: Duration::from_secs_f64(t),
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n_requests = 24;
    let rate = 6.0; // req/s
    let reqs = build_workload(n_requests, rate, 123);

    let mut texts: HashMap<&str, HashMap<u64, String>> = HashMap::new();
    for (policy, n_inst) in [
        (ServePolicy::AcceLlm, 2),
        (ServePolicy::Vllm, 2),
        (ServePolicy::Splitwise, 2), // 1 prefill + 1 decode
        (ServePolicy::AcceLlm, 4),
    ] {
        let cfg = ClusterConfig {
            artifacts_dir: "artifacts".into(),
            n_instances: n_inst,
            policy,
            slots: 8,
        };
        println!("\n================== {} x{} ==================",
                 policy.name(), n_inst);
        let report = serve_trace(&cfg, &reqs)?;
        report.print_summary();
        assert_eq!(report.completed, n_requests, "not all requests finished");
        if n_inst == 2 {
            texts
                .entry(policy.name())
                .or_default()
                .extend(report.responses.iter().map(|r| (r.id, r.text.clone())));
        }
    }

    // Greedy decoding is deterministic and slot-isolated, so every policy
    // must generate the same text for the same request.
    let acc = &texts["accellm"];
    for other in ["vllm", "splitwise"] {
        for (id, text) in &texts[other] {
            assert_eq!(acc[id], *text,
                       "policy {other} diverged on request {id} — replica \
                        desync or slot corruption");
        }
    }
    println!("\ncross-policy text consistency: OK \
              ({} requests x 3 policies identical)", n_requests);
    Ok(())
}
